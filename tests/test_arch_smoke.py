"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness, plus a decode step where applicable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, FAMILIES, smoke_config
from repro.models.common import init_params
from repro.models.lm import decode_step, forward, init_cache, lm_loss

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, B=2, S=16, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    if cfg.family == "hubert":
        return {
            "features": jnp.array(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "mask": jnp.array(rng.random((B, S)) < 0.3),
            "targets": jnp.array(rng.integers(0, cfg.vocab, (B, S)),
                                 jnp.int32),
        }
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (B, S)),
                                 jnp.int32)}
    if cfg.family == "paligemma":
        batch["img_embeds"] = jnp.array(
            rng.normal(size=(B, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    if cfg.family == "hubert":
        logits, aux = forward(params, cfg, features=batch["features"],
                              feat_mask=batch["mask"])
    else:
        logits, aux = forward(params, cfg, batch["tokens"],
                              img_embeds=batch.get("img_embeds"))
    B, S = (batch.get("tokens") if "tokens" in batch
            else batch["features"][..., 0]).shape
    assert logits.shape == (B, S, cfg.vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), "NaN/Inf in logits"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_reduces_loss_direction(arch):
    """One SGD step on the smoke config: loss finite, grads finite."""
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, cfg, batch)
    assert jnp.isfinite(loss), f"loss={loss}"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0
    # apply and re-evaluate: loss should change (params are connected)
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = lm_loss(params2, cfg, batch)
    assert jnp.isfinite(loss2) and not jnp.allclose(loss, loss2)


DECODE_ARCHS = [a for a in ARCH_NAMES if FAMILIES[a] != "hubert"
                and FAMILIES[a] != "paligemma"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_step_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits."""
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 8
    tokens = jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full_logits, _ = forward(params, cfg, tokens)
    cache = init_cache(cfg, B, max_len=S + 4)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits.astype(jnp.float32)),
                               rtol=0.15, atol=0.05)


def test_decode_cache_shapes():
    cfg = smoke_config("zamba2-2.7b")
    cache = init_cache(cfg, batch=2, max_len=32)
    G = cfg.n_layers // cfg.shared_attn_every
    assert cache["k"].shape[0] == G
    assert cache["ssm"].shape[0] == cfg.n_layers

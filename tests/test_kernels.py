"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Each kernel is swept over shapes and dtypes and asserted allclose against
its ref.py oracle; cgra_exec is additionally checked BIT-EXACTLY against
the cycle-accurate simulator for every paper benchmark kernel on both the
HyCUBE and N2N fabrics (the Morpher validation flow, Table II).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.ops import ssd_op
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.rwkv6.ops import wkv6_op
from repro.kernels.rwkv6.ref import wkv6_ref

TOL = {jnp.float32: 2e-3, jnp.bfloat16: 5e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (1, 128, 128, 4, 4, 64),       # MHA, square
    (2, 64, 256, 8, 2, 32),        # GQA 4:1, cross lengths
    (1, 200, 200, 4, 1, 64),       # MQA, non-multiple of block
    (1, 32, 512, 4, 4, 128),       # long KV
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, Sq, Skv, H, KV, D, causal, window):
    if causal and Sq != Skv:
        pytest.skip("causal requires square for this oracle")
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), jnp.float32)
    got = flash_attention_op(q, k, v, causal=causal, window=window,
                             bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (2, 128, 4, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (2, 128, 4, 64)).astype(dtype)
    got = flash_attention_op(q, k, v, interpret=True).astype(jnp.float32)
    want = attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(got, want, atol=_tol(dtype), rtol=_tol(dtype))


# ---------------------------------------------------------------------------
# rwkv6 chunked WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,chunk", [
    (1, 32, 2, 8, 16),
    (2, 70, 3, 16, 32),            # ragged final chunk
    (1, 128, 1, 64, 32),
    (2, 33, 4, 8, 32),             # single ragged chunk
])
def test_wkv6_sweep(B, S, H, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, K), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, K), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, K), jnp.float32)
    lw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (B, S, H, K))), -8.0)
    u = jax.random.normal(ks[4], (H, K))
    got = wkv6_op(r, k, v, lw, u, chunk=chunk, interpret=True)
    want = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (1, 64, 2, 16)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 16)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 16)).astype(dtype)
    lw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (1, 64, 2, 16))),
                     -8.0).astype(dtype)
    u = jax.random.normal(ks[4], (2, 16)).astype(dtype)
    got = wkv6_op(r, k, v, lw, u, interpret=True).astype(jnp.float32)
    want = wkv6_ref(r, k, v, lw, u).astype(jnp.float32)
    np.testing.assert_allclose(got, want, atol=_tol(dtype), rtol=5e-2)


def test_wkv6_matches_model_chunked():
    """The model's pure-jnp chunked path == the kernel (same algorithm)."""
    from repro.models.rwkv6 import wkv6_chunked
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    shape = (2, 48, 2, 8)
    r, k, v = (jax.random.normal(ks[i], shape, jnp.float32) for i in range(3))
    lw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], shape)), -8.0)
    u = jax.random.normal(ks[4], (2, 8))
    got = wkv6_op(r, k, v, lw, u, chunk=32, interpret=True)
    want = wkv6_chunked(r, k, v, lw, u, chunk=32)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# mamba2 SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 8, 8, 16),
    (2, 70, 3, 8, 12, 32),         # ragged final chunk
    (1, 128, 2, 16, 16, 64),
])
def test_ssd_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A_log = jax.random.normal(ks[4], (H,)) * 0.5
    D = jax.random.normal(ks[5], (H,))
    got = ssd_op(x, dt, A_log, Bm, Cm, D, chunk=chunk, interpret=True)
    want = ssd_ref(x, dt, A_log, Bm, Cm, D)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_ssd_matches_model_chunked():
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (2, 48, 2, 8), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 48, 2)))
    Bm = jax.random.normal(ks[2], (2, 48, 8))
    Cm = jax.random.normal(ks[3], (2, 48, 8))
    A_log = jax.random.normal(ks[4], (2,)) * 0.5
    D = jax.random.normal(ks[5], (2,))
    got = ssd_op(x, dt, A_log, Bm, Cm, D, chunk=16, interpret=True)
    want = ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# cgra_exec: bit-exact vs the cycle-accurate simulator (Morpher validation)
# ---------------------------------------------------------------------------

def _mapped(kernel_name, fabric):
    """Compile via the UAL so identical pairs are mapped once per session
    (the conftest installs a shared mapping cache)."""
    from repro import ual
    program = ual.Program.from_kernel(kernel_name,
                                      n_banks=fabric.n_mem_ports)
    exe = ual.compile(program, ual.Target(fabric))
    assert exe.success, f"{kernel_name} failed to map on {fabric.name}"
    return exe.map_result, program.layout, program.make_mem, program.n_iters


@pytest.mark.parametrize("kernel_name", ["gemm", "fft", "adpcm", "aes",
                                         "disparity", "dct", "nw"])
def test_cgra_exec_bitexact_hycube(kernel_name):
    from repro.core.adl import hycube
    from repro.core.dfg import flat_memory
    from repro.kernels.cgra_exec.ops import cgra_exec_op
    from repro.kernels.cgra_exec.ref import cgra_exec_ref
    fab = hycube(4, 4)
    res, layout, mk, n_iters = _mapped(kernel_name, fab)
    rng = np.random.default_rng(5)
    mems = np.stack([flat_memory(layout, mk(rng)) for _ in range(3)])
    got = cgra_exec_op(res.config, mems, n_iters)
    want = cgra_exec_ref(res.config, mems, n_iters)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("kernel_name", ["gemm", "nw"])
def test_cgra_exec_bitexact_n2n(kernel_name):
    from repro.core.adl import n2n
    from repro.core.dfg import flat_memory
    from repro.kernels.cgra_exec.ops import cgra_exec_op
    from repro.kernels.cgra_exec.ref import cgra_exec_ref
    fab = n2n(4, 4)
    res, layout, mk, n_iters = _mapped(kernel_name, fab)
    rng = np.random.default_rng(6)
    mems = np.stack([flat_memory(layout, mk(rng)) for _ in range(2)])
    got = cgra_exec_op(res.config, mems, n_iters)
    want = cgra_exec_ref(res.config, mems, n_iters)
    np.testing.assert_array_equal(got, want)


def test_cgra_exec_matches_dfg_oracle():
    """Three-way agreement: DFG interpreter == simulator == Pallas kernel."""
    from repro.core.adl import hycube
    from repro.core.dfg import flat_memory, interpret, unflatten_memory
    from repro.core.kernel_lib import KERNELS
    from repro.kernels.cgra_exec.ops import cgra_exec_op
    fab = hycube(4, 4)
    dfg, mk, n_iters = KERNELS["gemm"]()
    res, layout, mk, n_iters = _mapped("gemm", fab)
    rng = np.random.default_rng(9)
    mem_named = mk(rng)
    expect = interpret(dfg, mem_named, n_iters)
    flat = flat_memory(layout, mem_named)[None]
    out = cgra_exec_op(res.config, flat, n_iters)[0]
    got = unflatten_memory(layout, out, dfg.arrays)
    for name in dfg.outputs:
        np.testing.assert_array_equal(got[name], expect[name])

"""Streaming elastic execution: the double-buffered pipelined path.

The contract under test:

  * streamed chunks are bit-exact vs the discrete ``run_batch`` path and
    the unpadded DFG-interpreter oracle — including a ragged final chunk
    and the chunk == 1 degenerate,
  * streaming on a warm engine adds ZERO traces, and cold streaming
    traffic stays O(#buckets) (monkeypatch-counted on the shared
    ``make_cgra_call`` constructor, PR-5 pattern),
  * the stream summary schema: ``stream_chunks``, ``overlap_frac`` in
    [0, 1], ``throughput_sps``, mirrored into ``last_info`` and the
    engine's ``streams``/``stream_chunks`` counters,
  * ``Service.submit_stream`` pipelines one tenant's chunked request
    bit-exact while discrete tenants' micro-batches interleave, surfaces
    aggregate stream stats under ``stats()["stream"]``, and keeps the
    admission verdicts (all-or-nothing ``queue-full``, ``shutdown``),
  * the satellite fast paths: a batch that IS a bucket size skips the
    pad/copy staging entirely, and ``validate``'s multi-backend sweep
    flattens its test vectors exactly once.
"""
import numpy as np
import pytest

from repro import ual
from repro.core.dfg import interpret
from repro.ual.engine import CompiledKernelCache

N_ITERS = 6


@pytest.fixture(scope="module")
def compiled():
    program = ual.Program.from_kernel("gemm", bank_words=64)
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    exe = ual.compile(program, target)
    assert exe.success
    return program, exe


def _mems(program, B, seed=0):
    rng = np.random.default_rng(seed)
    return [program.random_inputs(rng) for _ in range(B)]


def _drain(gen):
    """Consume a streaming generator; returns (chunks, summary)."""
    chunks = []
    while True:
        try:
            chunks.append(next(gen))
        except StopIteration as stop:
            return chunks, dict(stop.value or {})


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,chunk", [(37, 8), (32, 32), (9, 1), (70, 32)])
def test_stream_bitexact_vs_run_batch_and_oracle(compiled, B, chunk):
    """Every chunking — ragged tail (37 @ 8), exact bucket (32 @ 32),
    chunk == 1, beyond-ladder B — matches the discrete path and the
    oracle bit for bit, in order."""
    program, exe = compiled
    mems = _mems(program, B, seed=B)
    ref = exe.run_batch(mems, n_iters=N_ITERS)
    chunks, summary = _drain(exe.run_stream(mems, n_iters=N_ITERS,
                                            chunk=chunk))
    flat = [d for c in chunks for d in c]
    assert len(flat) == B
    assert sum(len(c) for c in chunks) == B
    for m, got, want in zip(mems, flat, ref):
        oracle = interpret(program.dfg, m, N_ITERS)
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], want[name])
            np.testing.assert_array_equal(got[name], oracle[name])
    assert summary["stream_chunks"] == len(chunks)


def test_run_batch_stream_flag_collects_and_reports(compiled):
    """``run_batch(stream=True)`` returns the flat result list and lands
    the stream summary in ``last_info``."""
    program, exe = compiled
    mems = _mems(program, 20, seed=3)
    ref = exe.run_batch(mems, n_iters=N_ITERS)
    outs = exe.run_batch(mems, n_iters=N_ITERS, stream=True, chunk=8)
    for got, want in zip(outs, ref):
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], want[name])
    info = exe.last_info
    assert info["stream"] is True
    assert info["batch"] == 20
    assert info["stream_chunks"] == 3
    assert 0.0 <= info["overlap_frac"] <= 1.0
    assert info["throughput_sps"] > 0


def test_stream_chunked_sync_fallback_on_sim(compiled):
    """Backends without an async device path fall back to chunked
    synchronous delivery — same results, honest overlap_frac == 0."""
    program, exe = compiled
    mems = _mems(program, 5, seed=4)
    ref = exe.run_batch(mems, n_iters=N_ITERS, backend="sim")
    chunks, summary = _drain(exe.run_stream(mems, n_iters=N_ITERS,
                                            backend="sim", chunk=2))
    flat = [d for c in chunks for d in c]
    for got, want in zip(flat, ref):
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], want[name])
    assert summary["streamed"] == "chunked-sync"
    assert summary["stream_chunks"] == 3
    assert summary["overlap_frac"] == 0.0


# ---------------------------------------------------------------------------
# trace economy
# ---------------------------------------------------------------------------

def test_warm_engine_streams_with_zero_new_traces(compiled):
    """Streaming rides the same bucket-ladder traces as ``run``: after a
    warmup, a whole streamed sweep (ragged tail included) adds none."""
    program, exe = compiled
    cache = CompiledKernelCache()
    eng = cache.engine_for(exe.lowered)
    eng.warmup(program.layout.total_words)
    before = eng.traces
    flats = program.flatten_batch(_mems(program, 37, seed=9))
    rows, summary = [], None
    gen = eng.run_stream(flats, N_ITERS, chunk=8)
    while True:
        try:
            out, _cinfo = next(gen)
        except StopIteration as stop:
            summary = dict(stop.value or {})
            break
        rows.append(out)
    assert eng.traces == before
    assert summary["traced"] == 0
    assert sum(len(r) for r in rows) == 37


def test_cold_stream_traces_bounded_by_ladder(compiled, monkeypatch):
    """Cold streaming traffic traces at most once per ladder bucket —
    proved by counting ``pallas_call`` constructions (PR-5 pattern)."""
    import repro.ual.engine as engine_mod

    program, exe = compiled
    builds = []
    real = engine_mod.make_cgra_call
    monkeypatch.setattr(engine_mod, "make_cgra_call",
                        lambda *a, **k: builds.append(1) or real(*a, **k))
    cache = CompiledKernelCache(buckets=(1, 8))
    flats = program.flatten_batch(_mems(program, 8, seed=10))
    for B, chunk in ((7, 8), (8, 4), (3, 1), (8, 8)):
        gen = cache.run_stream(exe.lowered, flats[:B], N_ITERS, chunk=chunk)
        _drain_rows = []
        while True:
            try:
                out, _ = next(gen)
            except StopIteration:
                break
            _drain_rows.append(out)
        assert sum(len(r) for r in _drain_rows) == B
    eng = cache.engine_for(exe.lowered)
    assert len(builds) == eng.traces <= 2
    assert eng.streams == 4


# ---------------------------------------------------------------------------
# metrics schema
# ---------------------------------------------------------------------------

def test_stream_summary_schema_and_engine_counters(compiled):
    program, exe = compiled
    cache = CompiledKernelCache()
    eng = cache.engine_for(exe.lowered)
    flats = program.flatten_batch(_mems(program, 17, seed=12))
    gen = eng.run_stream(flats, N_ITERS, chunk=8)
    n = 0
    while True:
        try:
            out, cinfo = next(gen)
        except StopIteration as stop:
            summary = dict(stop.value or {})
            break
        assert cinfo["chunk"] == n
        assert cinfo["samples"] == len(out)
        assert cinfo["bucket"] >= len(out)
        n += 1
    for key in ("stream_chunks", "samples", "overlap_frac",
                "throughput_sps", "wall_s", "wait_s", "traced", "engine"):
        assert key in summary, key
    assert summary["stream_chunks"] == n == 3
    assert summary["samples"] == 17
    assert 0.0 <= summary["overlap_frac"] <= 1.0
    assert summary["throughput_sps"] > 0
    stats = eng.stats()
    assert stats["streams"] == 1
    assert stats["stream_chunks"] == 3
    agg = cache.stats()
    assert agg["streams"] == 1 and agg["stream_chunks"] == 3


# ---------------------------------------------------------------------------
# service: submit_stream
# ---------------------------------------------------------------------------

def test_submit_stream_interleaves_with_discrete_tenants(compiled):
    """One bulk tenant's chunked stream and a discrete tenant's singles
    share the service: both resolve bit-exact, spans are bounded (no
    coalescer monopolization — more than one span for a long stream),
    and stream stats surface under ``stats()['stream']``."""
    program, exe = compiled
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    mems = _mems(program, 70, seed=20)
    ref = exe.run_batch(mems, n_iters=N_ITERS)
    with ual.Service(max_batch=16, max_wait_ms=2.0, max_queue=512) as svc:
        d_futs = [svc.submit(program, target, m, tenant="discrete",
                             n_iters=N_ITERS) for m in mems[:10]]
        sr = svc.submit_stream(program, target, mems, tenant="bulk",
                               n_iters=N_ITERS, chunk=8, span=2)
        assert len(sr) == 70
        got = []
        for chunk_outs in sr.chunks(timeout=300):
            assert len(chunk_outs) <= 8
            got.extend(chunk_outs)
        d_outs = [f.result(timeout=300) for f in d_futs]
        stats = svc.stats()
    for g, want in zip(got, ref):
        for name in program.outputs:
            np.testing.assert_array_equal(g[name], want[name])
    for g, want in zip(d_outs, ref[:10]):
        for name in program.outputs:
            np.testing.assert_array_equal(g[name], want[name])
    # 70 samples at chunk=8, span=2 -> ceil(70/16) = 5 spans
    assert stats["stream"]["spans"] == 5
    assert stats["stream"]["samples"] == 70
    assert stats["stream"]["chunks"] >= 9
    assert stats["stream"]["samples_per_s"] > 0
    info = sr.info
    assert info["spans"] == 5 and info["samples"] == 70
    assert 0.0 <= info["overlap_frac"] <= 1.0
    assert sr.responses[0].info.get("stream") is True
    # discrete traffic still coalesced normally alongside the stream
    assert stats["completed"] == 80


def test_submit_stream_queue_full_is_all_or_nothing(compiled):
    program, _exe = compiled
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    mems = _mems(program, 24, seed=21)
    svc = ual.Service(max_batch=8, max_queue=16, start=False)
    try:
        sr = svc.submit_stream(program, target, mems, n_iters=N_ITERS)
        assert sr.rejected and sr.reason == "queue-full"
        assert all(r.rejected for r in sr.responses)
        # a fitting stream is still admitted after the rejection
        ok = svc.submit_stream(program, target, mems[:4], n_iters=N_ITERS)
        assert not ok.done() or not ok.rejected
    finally:
        svc.shutdown()
    assert all(r.rejected and r.reason == "shutdown" for r in ok.responses)


def test_submit_stream_after_shutdown_rejected(compiled):
    program, _exe = compiled
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    svc = ual.Service(max_batch=8)
    svc.shutdown()
    sr = svc.submit_stream(program, target, _mems(program, 3, seed=22),
                           n_iters=N_ITERS)
    assert sr.rejected and sr.reason == "shutdown"
    assert svc.stats()["stream"]["spans"] == 0


def test_submit_stream_empty_is_a_noop(compiled):
    program, _exe = compiled
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    with ual.Service(max_batch=8) as svc:
        sr = svc.submit_stream(program, target, [], n_iters=N_ITERS)
        assert len(sr) == 0 and sr.done() and not sr.rejected
        assert sr.results() == []


# ---------------------------------------------------------------------------
# satellites: pad-free fast path, validate flatten-once
# ---------------------------------------------------------------------------

def test_exact_bucket_batch_skips_padding(compiled):
    """A batch whose size IS a bucket takes the pad-free fast path: no
    padded samples, results still bit-exact."""
    program, exe = compiled
    cache = CompiledKernelCache()
    eng = cache.engine_for(exe.lowered)
    mems = _mems(program, 8, seed=30)
    flats = program.flatten_batch(mems)
    out, info = eng.run(flats, N_ITERS)
    assert info["padded"] == 0
    assert eng.padded_samples == 0
    want = interpret(program.dfg, mems[0], N_ITERS)
    got = program.unflatten(out[0])
    for name in program.outputs:
        np.testing.assert_array_equal(got[name], want[name])
    # a non-bucket size still pads (the fast path is conditional)
    out7, info7 = eng.run(flats[:7], N_ITERS)
    assert info7["padded"] == 1
    assert out7.shape[0] == 7


def test_validate_flattens_once_per_multi_backend_sweep(compiled,
                                                        monkeypatch):
    program, exe = compiled
    calls = []
    real = ual.Program.flatten_batch
    monkeypatch.setattr(ual.Program, "flatten_batch",
                        lambda self, ms: calls.append(len(ms))
                        or real(self, ms))
    report = exe.validate(seed=5, backends=("sim", "pallas"), n_vectors=4)
    assert report.passed
    assert calls == [4]          # one flatten feeds both backend sweeps

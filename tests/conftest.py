"""Shared fixtures: one UAL mapping cache for the whole test session.

Mapping dominates the suite's wall time, and many tests compile the same
``(kernel, fabric)`` pairs.  Installing a session-wide cache (in-process
dict + tmp disk dir) as the UAL default means the first test to compile a
pair pays the mapper cost and every later test — in any file — hits the
cache, including indirect consumers like ``core.validate.validate_kernel``.
"""
import pytest

from repro import ual


@pytest.fixture(scope="session", autouse=True)
def ual_cache(tmp_path_factory):
    """Session-scoped mapping cache, installed as the process default."""
    cache = ual.MappingCache(disk_dir=tmp_path_factory.mktemp("ual_cache"))
    prev = ual.set_default_cache(cache)
    yield cache
    ual.set_default_cache(prev)

"""Unified abstraction layer tests: backend parity, mapping cache,
registry error handling, and digest stability.

The UAL contract under test:

  * every backend executes the same machine configuration bit-exactly
    (interp oracle == sim == pallas),
  * ``compile()`` of an identical ``(Program, Target)`` pair is served
    from the cache — zero mapper restarts, >= 10x faster than cold —
    both in-process and across processes (disk layer),
  * registries fail loudly: unknown names raise with the known set,
    duplicate registration raises without ``overwrite=True``,
  * ``Program.digest`` is a content hash: stable across processes,
    sensitive to structural change.
"""
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import ual
from repro.core.adl import hycube
from repro.core.dfg import DFGBuilder

PARITY_KERNELS = ("gemm", "nw")


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kname", PARITY_KERNELS)
def test_backend_parity_bitexact(kname):
    """interp == sim == pallas on the same compiled executable."""
    program = ual.Program.from_kernel(kname)
    exe = ual.compile(program, ual.Target.from_name("hycube", rows=4, cols=4))
    mem = program.random_inputs(np.random.default_rng(0))
    outs = {b: exe.run(backend=b, **mem) for b in ("interp", "sim", "pallas")}
    for name in program.outputs:
        np.testing.assert_array_equal(outs["sim"][name], outs["interp"][name])
        np.testing.assert_array_equal(outs["pallas"][name],
                                      outs["interp"][name])


def test_run_batch_matches_per_item():
    """pallas' native batch path == item-by-item sim execution."""
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target.from_name("hycube", rows=4, cols=4,
                                                    backend="pallas"))
    rng = np.random.default_rng(7)
    mems = [program.random_inputs(rng) for _ in range(3)]
    batched = exe.run_batch(mems)
    assert exe.last_info.get("batched")
    for m, got in zip(mems, batched):
        want = exe.run(backend="sim", **m)
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], want[name])


def test_validate_refuses_oracle_vs_itself():
    """interp is the oracle: validating it against itself is vacuous."""
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target(hycube(4, 4), backend="interp"))
    with pytest.raises(ValueError, match="IS the validation oracle"):
        exe.validate()
    with pytest.raises(ValueError, match="IS the validation oracle"):
        exe.validate(backends=("sim", "interp"))


def test_validate_multi_backend():
    program = ual.Program.from_kernel("nw")
    exe = ual.compile(program, ual.Target.from_name("hycube", rows=4, cols=4))
    rep = exe.validate(seed=5, backends=("sim", "pallas"))
    assert rep.passed
    assert rep.backend_results == {"sim": True, "pallas": True}
    assert rep.sim_stats is not None


# ---------------------------------------------------------------------------
# mapping cache
# ---------------------------------------------------------------------------

def test_cache_round_trip_zero_restarts_and_10x(tmp_path):
    """Acceptance: the second compile of an identical pair hits the cache —
    zero mapper restarts and >= 10x lower wall time than the cold compile."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("fft")
    target = ual.Target.from_name("hycube", rows=4, cols=4)

    t0 = time.perf_counter()
    cold = ual.compile(program, target, cache=cache)
    t_cold = time.perf_counter() - t0
    assert cold.success and not cold.compile_info.cache_hit
    assert cold.compile_info.mapper_restarts >= 1
    assert cache.stats.misses == 1 and cache.stats.stores == 1

    t0 = time.perf_counter()
    warm = ual.compile(program, target, cache=cache)
    t_warm = time.perf_counter() - t0
    assert warm.compile_info.cache_hit
    assert warm.compile_info.mapper_restarts == 0
    assert cache.stats.hits == 1
    assert warm.II == cold.II
    assert t_warm < t_cold / 10, (t_cold, t_warm)

    # cross-process path: drop the in-process layer, hit the disk pickle
    cache.clear_memory()
    t0 = time.perf_counter()
    disk = ual.compile(program, target, cache=cache)
    t_disk = time.perf_counter() - t0
    assert disk.compile_info.cache_hit
    assert disk.compile_info.mapper_restarts == 0
    assert cache.stats.disk_hits == 1
    assert disk.II == cold.II
    np.testing.assert_array_equal(disk.map_result.config.opcode,
                                  cold.map_result.config.opcode)
    assert t_disk < t_cold / 10, (t_cold, t_disk)


def test_cache_shared_across_backends(tmp_path):
    """Target.digest excludes the backend: parity costs one mapping."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    sim = ual.Target.from_name("hycube", rows=4, cols=4, backend="sim")
    ual.compile(program, sim, cache=cache)
    exe = ual.compile(program, sim.with_backend("pallas"), cache=cache)
    assert exe.compile_info.cache_hit
    assert cache.stats.misses == 1 and cache.stats.hits == 1


def test_cache_aggregate_stats_view(tmp_path):
    """``cache.stats`` holds the raw counters; CALLING it —
    ``cache.stats()`` — returns the aggregate view: hit/miss ratios and
    on-disk entry counts for both the mapping and lowered tables."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    target = ual.Target.from_name("hycube", rows=4, cols=4)
    ual.compile(program, target, cache=cache)          # cold: 1 miss
    ual.compile(program, target, cache=cache)          # warm: 1 hit

    agg = cache.stats()
    assert set(agg) == {"mapping", "lowered", "quarantined"}
    for layer in (agg["mapping"], agg["lowered"]):
        assert layer["lookups"] == 2
        assert layer["hit_ratio"] == 0.5
        assert layer["stores"] == 1
        assert layer["disk_entries"] == 1              # one pair on disk
    assert agg["quarantined"] == 0                     # nothing poisoned
    # the raw counters stay reachable exactly as before
    assert cache.stats.hits == 1 and cache.stats.lowered_hits == 1

    empty = ual.MappingCache(disk_dir=None)
    agg = empty.stats()
    assert agg["mapping"]["hit_ratio"] is None         # no lookups yet
    assert agg["lowered"]["disk_entries"] == 0         # diskless


def test_cache_keys_distinguish_targets(tmp_path):
    """Different fabrics / mapper knobs must not collide."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    ual.compile(program, ual.Target(hycube(4, 4, max_hops=4)), cache=cache)
    ual.compile(program, ual.Target(hycube(4, 4, max_hops=1)), cache=cache)
    ual.compile(program, ual.Target(hycube(4, 4, max_hops=4), seed=9),
                cache=cache)
    assert cache.stats.misses == 3 and cache.stats.hits == 0


def test_label_fn_bypasses_cache(tmp_path):
    """A placement-bias hook is unhashable state: always compile cold."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    target = ual.Target(hycube(4, 4), label_fn=lambda nid, pe, ii: 0.0)
    exe = ual.compile(program, target, cache=cache)
    exe2 = ual.compile(program, target, cache=cache)
    assert exe.success and exe2.success
    assert not exe2.compile_info.cache_hit
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_unknown_backend_raises_with_known_set():
    program = ual.Program.from_kernel("gemm")
    with pytest.raises(KeyError, match="unknown backend 'vhdl'.*interp"):
        ual.compile(program, ual.Target(hycube(4, 4), backend="vhdl"))


def test_duplicate_backend_registration_raises():
    class Dummy(ual.Backend):
        def execute(self, program, result, mem, n_iters):
            return mem, {}

    ual.register_backend("dummy_test_backend", Dummy())
    try:
        with pytest.raises(ValueError, match="already registered"):
            ual.register_backend("dummy_test_backend", Dummy())
        ual.register_backend("dummy_test_backend", Dummy(), overwrite=True)
        assert "dummy_test_backend" in ual.list_backends()
    finally:
        ual.backends._BACKENDS.pop("dummy_test_backend", None)


def test_backend_must_subclass_backend():
    with pytest.raises(TypeError, match="must be a ual.backends.Backend"):
        ual.register_backend("broken", lambda *a: None)


def test_unknown_fabric_and_kernel_raise():
    with pytest.raises(KeyError, match="unknown fabric 'fpga'.*hycube"):
        ual.Target.from_name("fpga")
    with pytest.raises(KeyError, match="unknown kernel 'nope'"):
        ual.Program.from_kernel("nope")


def test_custom_backend_end_to_end():
    """The ROADMAP's "writing a custom backend" snippet actually works: a
    backend that executes via the interpreter but tags its info dict."""
    from repro.core.dfg import interpret

    class TracingBackend(ual.Backend):
        requires_config = False

        def execute(self, program, result, mem, n_iters):
            out = interpret(program.dfg, mem, n_iters)
            return out, {"traced": program.name}

    ual.register_backend("tracing_test", TracingBackend())
    try:
        program = ual.Program.from_kernel("gemm")
        exe = ual.compile(program, ual.Target(hycube(4, 4),
                                              backend="tracing_test"))
        out = exe.run(**program.random_inputs(np.random.default_rng(0)))
        assert exe.last_info == {"traced": "gemm"}
        assert "C" in out
    finally:
        ual.backends._BACKENDS.pop("tracing_test", None)


# ---------------------------------------------------------------------------
# digests
# ---------------------------------------------------------------------------

def test_program_digest_stable_across_processes():
    """The digest is a content hash, not an id() artifact: a fresh process
    computes the same value."""
    import os
    from pathlib import Path
    digest = ual.Program.from_kernel("gemm").digest
    code = ("from repro import ual; "
            "print(ual.Program.from_kernel('gemm').digest)")
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, check=True, env=env, cwd=str(repo))
    assert out.stdout.strip() == digest


def test_program_digest_sensitivity():
    """Structurally different programs hash differently; identical ones
    hash identically (name and n_iters excluded by design)."""
    def build(const):
        b = DFGBuilder("sens")
        b.array("x", 8)
        b.array("out", 8, output=True)
        i = b.counter()
        b.store("out", i, b.op("ADD", b.load("x", i), const))
        return ual.Program.from_builder(b, n_iters=8)

    assert build(3).digest == build(3).digest
    assert build(3).digest != build(4).digest
    renamed = ual.Program.from_kernel("gemm")
    assert renamed.digest == ual.Program.from_kernel("gemm").digest


def test_target_digest_covers_knobs_not_backend():
    t = ual.Target.from_name("hycube", rows=4, cols=4)
    assert t.digest == t.with_backend("pallas").digest
    assert t.digest != ual.Target.from_name("hycube", rows=4, cols=4,
                                            seed=1).digest
    assert t.digest != ual.Target.from_name("hycube", rows=4, cols=4,
                                            ii_max=32).digest


# ---------------------------------------------------------------------------
# frontends + spatial targets
# ---------------------------------------------------------------------------

def test_program_from_function_traced():
    program = ual.Program.from_function(
        lambda x, y: x * y + 1, {"x": 8, "y": 8}, name="traced_mul")
    exe = ual.compile(program, ual.Target(hycube(4, 4)))
    rng = np.random.default_rng(0)
    x = rng.integers(-10, 10, 8).astype(np.int32)
    y = rng.integers(-10, 10, 8).astype(np.int32)
    out = exe.run(x=x, y=y)
    np.testing.assert_array_equal(out["out"], x * y + 1)


def test_spatial_target_analytic_model():
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target.from_name("spatial",
                                                    backend="interp"))
    assert exe.success and exe.II >= 1 and exe.spatial_subgraphs >= 1
    # spatial fabrics have no machine configuration: sim must refuse
    with pytest.raises(RuntimeError, match="machine configuration"):
        exe.run(backend="sim")


def test_run_rejects_unknown_array():
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target(hycube(4, 4)))
    with pytest.raises(KeyError, match="unknown array"):
        exe.run(bogus=np.zeros(4, np.int32))


def test_run_dict_form_handles_colliding_array_names():
    """Arrays named like run() parameters must work via the dict form."""
    program = ual.Program.from_function(
        lambda n_iters: n_iters + 1, {"n_iters": 8}, name="collide")
    exe = ual.compile(program, ual.Target(hycube(4, 4)))
    x = np.arange(8, dtype=np.int32)
    out = exe.run({"n_iters": x})
    np.testing.assert_array_equal(out["out"], x + 1)
    assert exe.validate(seed=0).passed


def test_failed_mapping_reports_mapping_failure(tmp_path):
    """A temporal mapping that fails must say so, not claim the executable
    is mapping-free — and the failure is memoized in-process (so repeat
    compiles are free) but never pinned on disk (failure can be
    wall-clock dependent via the time budget)."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("dct")       # 79 nodes
    target = ual.Target(hycube(2, 2), ii_max=1)
    exe = ual.compile(program, target, cache=cache)
    assert not exe.success
    with pytest.raises(RuntimeError, match="mapping onto .* failed"):
        exe.run(x=np.zeros(8, np.int32))
    again = ual.compile(program, target, cache=cache)
    assert again.compile_info.cache_hit and not again.success
    assert not list((tmp_path / "ual").glob("*.pkl"))   # nothing on disk
    cache.clear_memory()
    cold = ual.compile(program, target, cache=cache)
    assert not cold.compile_info.cache_hit               # retried for real

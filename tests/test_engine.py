"""Persistent JIT execution engine: trace-once/run-many for the pallas path.

The contract under test:

  * batch-bucket padding is semantically invisible — batch sizes that
    straddle bucket boundaries (1, 7, 9, 33, 129) are bit-exact against
    the scalar reference engine and the unpadded DFG-interpreter oracle,
  * the trace counter does not grow with repeated same-bucket calls
    (monkeypatch-counted on the shared ``make_cgra_call`` constructor),
    and stays O(#buckets) under mixed-size traffic,
  * ``n_iters`` is traced: one warm trace serves every iteration count,
  * ``Executable.warmup(buckets=...)`` pre-traces the ladder and records
    engine stats in ``last_info``,
  * external ``cgra_exec_op(..., linked=None)`` callers never lower the
    same configuration twice (the fingerprint memo),
  * ``Program.flatten_batch``/``unflatten_batch`` match the per-sample
    scalar paths exactly (including missing / short arrays),
  * ``Service.stats()`` surfaces the engine aggregate.
"""
import numpy as np
import pytest

from repro import ual
from repro.core.dfg import interpret
from repro.core.simulator import simulate_reference
from repro.ual.engine import CompiledKernelCache, bucket_ladder

N_ITERS = 6


@pytest.fixture(scope="module")
def compiled():
    """One small-scratchpad gemm compile shared by the module (smaller
    bank_words keep the interpret-mode traces cheap)."""
    program = ual.Program.from_kernel("gemm", bank_words=64)
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    exe = ual.compile(program, target)
    assert exe.success
    return program, exe


def _mems(program, B, seed=0):
    rng = np.random.default_rng(seed)
    return [program.random_inputs(rng) for _ in range(B)]


# ---------------------------------------------------------------------------
# bucket-padding correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [1, 7, 9, 33, 129])
def test_bucket_straddling_batches_bitexact(compiled, B):
    """Sizes straddling every bucket boundary of the (1, 8, 32, 128)
    ladder — including B=129, which runs as a warm largest-bucket chunk
    plus a bucket-1 tail — are bit-exact vs the unpadded oracle, and
    (spot-checked first/last sample) vs the scalar reference engine."""
    program, exe = compiled
    mems = _mems(program, B, seed=B)
    outs = exe.run_batch(mems, n_iters=N_ITERS)
    assert exe.last_info["batch"] == B
    for m, got in zip(mems, outs):
        want = interpret(program.dfg, m, N_ITERS)
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], want[name])
    for b in (0, B - 1):
        flat = program.flatten(mems[b])
        ref, _ = simulate_reference(exe.map_result.config, flat, N_ITERS)
        refd = program.unflatten(ref)
        for name in program.outputs:
            np.testing.assert_array_equal(outs[b][name], refd[name])


def test_dynamic_n_iters_shares_one_trace(compiled):
    """The trip count is a traced scalar: different n_iters on one bucket
    reuse the same trace, and each still matches the oracle."""
    program, exe = compiled
    be = ual.get_backend("pallas")
    eng = be.engine.engine_for(exe.lowered, lanes=be.lanes,
                               interpret=be.interpret)
    mems = _mems(program, 4, seed=42)
    exe.run_batch(mems, n_iters=3)           # warm (or reuse) bucket 8
    before = eng.traces
    for n in (1, 5, 11):
        outs = exe.run_batch(mems, n_iters=n)
        for m, got in zip(mems, outs):
            want = interpret(program.dfg, m, n)
            for name in program.outputs:
                np.testing.assert_array_equal(got[name], want[name])
    assert eng.traces == before


# ---------------------------------------------------------------------------
# trace accounting
# ---------------------------------------------------------------------------

def test_trace_counter_static_across_same_bucket_calls(compiled,
                                                       monkeypatch):
    """Repeated calls landing in one bucket must not grow the trace
    counter — proved by counting invocations of the ``pallas_call``
    constructor (which runs exactly once per trace)."""
    import repro.ual.engine as engine_mod

    program, exe = compiled
    builds = []
    real = engine_mod.make_cgra_call
    monkeypatch.setattr(engine_mod, "make_cgra_call",
                        lambda *a, **k: builds.append(1) or real(*a, **k))

    cache = CompiledKernelCache()            # fresh: no warm traces
    flats = program.flatten_batch(_mems(program, 8, seed=7))
    for B in (3, 8, 1, 5, 8, 2, 7, 4):       # buckets: {8, 1}
        out, info = cache.run(exe.lowered, flats[:B], N_ITERS)
        assert out.shape == (B, program.layout.total_words)
    eng = cache.engine_for(exe.lowered)
    assert len(builds) == 2                  # one per distinct bucket
    assert eng.traces == 2
    assert set(eng.bucket_calls) == {1, 8}
    assert eng.stats()["hit_ratio"] == pytest.approx(6 / 8)


def test_mixed_size_traffic_traces_bounded_by_ladder(compiled):
    """O(#buckets) traces no matter how traffic is shaped: 40 mixed-size
    calls on a fresh engine trace at most once per ladder bucket."""
    program, exe = compiled
    cache = CompiledKernelCache(buckets=(1, 4, 8))
    flats = program.flatten_batch(_mems(program, 8, seed=11))
    for i in range(40):
        B = 1 + i % 8
        cache.run(exe.lowered, flats[:B], N_ITERS)
    eng = cache.engine_for(exe.lowered)
    assert eng.buckets == (1, 4, 8)
    assert eng.traces <= len(eng.buckets)
    agg = cache.stats()
    assert agg["engines"] == 1 and agg["traces"] == eng.traces


def test_warmup_pre_traces_the_ladder(compiled):
    program, exe = compiled
    cache = CompiledKernelCache()
    prev = ual.set_default_engine(cache)
    try:
        stats = exe.warmup(buckets=(1, 8))
        assert stats["traces"] == 2
        assert exe.last_info["engine_stats"]["traces"] == 2
        exe.run_batch(_mems(program, 5, seed=3), n_iters=N_ITERS)
        assert exe.last_info["traced"] == 0    # warm bucket, no retrace
        assert exe.last_info["engine"] == "pallas-jit"
    finally:
        ual.set_default_engine(prev)


def test_bucket_ladder_validation():
    assert bucket_ladder(128) == (1, 8, 32, 128)
    assert bucket_ladder(16, (32, 4, 4, 1)) == (1, 4)   # capped + deduped
    with pytest.raises(ValueError):
        bucket_ladder(8, (16, 32))


# ---------------------------------------------------------------------------
# no path lowers one config twice
# ---------------------------------------------------------------------------

def test_cgra_exec_op_memoizes_lowering(compiled, monkeypatch):
    """External callers passing ``linked=None`` ride the per-process
    fingerprint memo instead of silently re-lowering per call."""
    import repro.kernels.cgra_exec.ops as ops

    program, exe = compiled
    ops._LINKED_MEMO.clear()
    lowers = []
    real = ops.link_config
    monkeypatch.setattr(ops, "link_config",
                        lambda cfg: lowers.append(1) or real(cfg))
    flats = program.flatten_batch(_mems(program, 2, seed=9))
    a = ops.cgra_exec_op(exe.map_result.config, flats, N_ITERS)
    b = ops.cgra_exec_op(exe.map_result.config, flats, N_ITERS)
    assert len(lowers) == 1
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# vectorized flatten/unflatten
# ---------------------------------------------------------------------------

def test_flatten_batch_matches_scalar_paths(compiled):
    program, _ = compiled
    mems = _mems(program, 5, seed=13)
    flats = program.flatten_batch(mems)
    want = np.stack([program.flatten(m) for m in mems])
    np.testing.assert_array_equal(flats, want)
    unflat = program.unflatten_batch(flats)
    for b, m in enumerate(unflat):
        scalar = program.unflatten(flats[b])
        assert set(m) == set(scalar)
        for name in m:
            np.testing.assert_array_equal(m[name], scalar[name])


def test_flatten_batch_ragged_and_missing_arrays(compiled):
    """Missing arrays zero-fill and short arrays zero-pad, exactly like
    the scalar path."""
    program, _ = compiled
    rng = np.random.default_rng(17)
    full = program.random_inputs(rng)
    name = program.inputs[0]
    short = dict(full)
    short[name] = full[name][: max(1, len(full[name]) // 2)]
    missing = {k: v for k, v in full.items() if k != name}
    mems = [full, short, missing]
    flats = program.flatten_batch(mems)
    want = np.stack([program.flatten(m) for m in mems])
    np.testing.assert_array_equal(flats, want)


def test_flatten_batch_rejects_unknown_arrays(compiled):
    program, _ = compiled
    with pytest.raises(KeyError, match="unknown array"):
        program.flatten_batch([{"nope": np.zeros(4, np.int32)}])


# ---------------------------------------------------------------------------
# service surface
# ---------------------------------------------------------------------------

def test_service_stats_surface_engine_aggregate():
    svc = ual.Service(start=False)
    try:
        snap = svc.stats()
        assert "engine" in snap
        assert {"engines", "traces", "hit_ratio"} <= set(snap["engine"])
    finally:
        svc.shutdown()

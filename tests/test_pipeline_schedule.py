"""Pipeline schedules: dependence verification + numerical equivalence."""
import numpy as np
import pytest

from repro.core.pipeline_schedule import (bubble_model, gpipe,
                                          interleaved_1f1b, one_f_one_b)


@pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (4, 16), (8, 16)])
def test_schedules_verify(S, M):
    for sched in (gpipe(S, M), one_f_one_b(S, M), interleaved_1f1b(S, M, 2)):
        sched.verify()


def test_gpipe_bubble_matches_closed_form():
    s = gpipe(4, 16)
    assert abs(s.bubble_fraction() - bubble_model(4, 16)) < 1e-9


def test_1f1b_memory_below_gpipe():
    g, o = gpipe(4, 16), one_f_one_b(4, 16)
    assert o.peak_in_flight() < g.peak_in_flight()
    # same bubble as GPipe
    assert abs(o.bubble_fraction() - g.bubble_fraction()) < 0.08


def test_interleaving_shrinks_bubble():
    o = one_f_one_b(4, 8)
    i = interleaved_1f1b(4, 8, 2)
    assert i.bubble_fraction() < o.bubble_fraction()


def test_schedule_numerical_equivalence():
    """Execute a toy 4-stage linear model under the 1F1B reservation table
    and check the result equals sequential execution (the pipeline analogue
    of Morpher's bitstream-vs-oracle validation)."""
    S, M = 4, 6
    rng = np.random.default_rng(0)
    Ws = [rng.normal(size=(8, 8)) * 0.3 for _ in range(S)]
    xs = [rng.normal(size=(8,)) for _ in range(M)]

    # sequential oracle: forward then "backward" (here: grad of sum(out))
    def fwd_stage(s, h):
        return np.tanh(Ws[s] @ h)

    oracle_out, oracle_grad = [], []
    for m in range(M):
        acts = [xs[m]]
        for s in range(S):
            acts.append(fwd_stage(s, acts[-1]))
        oracle_out.append(acts[-1])
        g = np.ones(8)
        for s in reversed(range(S)):
            g = Ws[s].T @ (g * (1 - acts[s + 1] ** 2))
        oracle_grad.append(g)

    sched = one_f_one_b(S, M)
    sched.verify()
    acts = {}        # (m, s) -> activation out of stage s
    grads = {}       # (m, s) -> gradient into stage s
    for row in sched.table:
        updates = []
        for s, slot in enumerate(row):
            if slot is None:
                continue
            phase, m, _ = slot
            if phase == "F":
                h_in = xs[m] if s == 0 else acts[(m, s - 1)]
                updates.append((("a", m, s), fwd_stage(s, h_in)))
            else:
                g_in = np.ones(8) if s == S - 1 else grads[(m, s + 1)]
                a = acts[(m, s)]
                updates.append((("g", m, s), Ws[s].T @ (g_in * (1 - a ** 2))))
        for key, val in updates:
            kind, m, s = key
            (acts if kind == "a" else grads)[(m, s)] = val
    for m in range(M):
        np.testing.assert_allclose(acts[(m, S - 1)], oracle_out[m], rtol=1e-12)
        np.testing.assert_allclose(grads[(m, 0)], oracle_grad[m], rtol=1e-12)

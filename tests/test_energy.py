"""PACE energy/area model calibration (paper Figs. 10-11, Table IV)."""
import numpy as np

from repro.core.energy import (AREA_SPLIT_CGRA, AREA_SPLIT_SOC, POWER_SPLIT,
                               cgra_power_mw, component_energy_pj,
                               efficiency_gops_w, freq_mhz, kernel_energy,
                               normalized_efficiency, table4_comparison)


def test_calibration_anchors():
    # Fig. 10 anchors: (0.6V, 4.4mW, 21MHz) and (1.0V, 43mW, 105MHz)
    assert abs(cgra_power_mw(0.6) - 4.4) < 0.5
    assert abs(cgra_power_mw(1.0) - 43.0) < 2.0
    assert abs(freq_mhz(0.6) - 21.0) < 1.0
    assert abs(freq_mhz(1.0) - 105.0) < 1.0


def test_efficiency_curve_shape():
    vs = np.arange(0.6, 1.01, 0.05)
    effs = [efficiency_gops_w(float(v)) for v in vs]
    assert effs[0] == max(effs)                 # peak at 0.6 V
    assert 320 <= effs[0] <= 400                # ~360 GOPS/W
    assert 140 <= effs[-1] <= 200               # ~154 GOPS/W at 1.0 V
    assert all(a >= b for a, b in zip(effs, effs[1:]))   # monotone falling


def test_splits_sum_to_one():
    for split in (POWER_SPLIT, AREA_SPLIT_CGRA, AREA_SPLIT_SOC):
        assert abs(sum(split.values()) - 1.0) < 1e-9
    assert POWER_SPLIT["cm"] == max(POWER_SPLIT.values())


def test_table4_pace_wins_normalized():
    rows = table4_comparison()
    pace = rows["PACE"]
    for k, r in rows.items():
        if k == "PACE":
            continue
        ratio = pace["norm_eff"] / r["norm_eff"]
        assert ratio > 1.0, f"PACE must beat {k} normalized"
        assert ratio < 5.0                      # paper: 1.2x - 4.6x
    assert pace["norm_area"] == min(r["norm_area"] for r in rows.values())


def test_normalization_rules():
    # norm eff scales by (node/40)^2: a 20nm design at 400 GOPS/W -> 100
    assert abs(normalized_efficiency(400.0, 20.0) - 100.0) < 1e-9


def test_kernel_energy_gating_saves():
    from repro.core.adl import pace
    from repro.core.dfg import apply_layout, plan_layout
    from repro.core.kernel_lib import KERNELS
    from repro.core.mapper import map_dfg
    dfg, _, n_iters = KERNELS["gemm"]()
    laid = apply_layout(dfg, plan_layout(dfg))
    res = map_dfg(laid, pace(), seed=0)
    assert res.success
    on = kernel_energy(res.config, n_iters, dynamic_gating=True)
    off = kernel_energy(res.config, n_iters, dynamic_gating=False)
    assert on["total"] < off["total"]
    sav = 1 - on["total"] / off["total"]
    assert 0.02 < sav < 0.35                   # paper: ~10% extra savings
    # CM must be the largest component (paper Fig. 11c)
    assert on["cm"] == max(v for k, v in on.items()
                           if k not in ("total", "per_op"))


def test_component_energy_positive():
    comp = component_energy_pj(0.6)
    assert all(v > 0 for v in comp.values())
    # HyCUBE test chip: 290 pJ/op at 0.9V full array — our per-PE-cycle
    # total at 0.6V should be within an order of magnitude
    assert 0.5 < sum(comp.values()) < 50.0

"""System-level tests: the end-to-end drivers and distributed-training
features (grad accumulation equivalence, int8 compression, restart)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, host_batch
from repro.launch.mesh import make_host_mesh
from repro.models.common import init_params
from repro.train.optimizer import OptConfig
from repro.train.train_step import (make_loss_and_grad,
                                    make_sharded_train_step,
                                    make_train_state)


def _batch(cfg, B=4, S=16, step=0):
    dc = DataConfig(global_batch=B, seq_len=S)
    return {k: jnp.asarray(v) for k, v in host_batch(cfg, dc, step).items()}


def test_microbatch_accumulation_matches_full_batch():
    cfg = smoke_config("h2o-danube-1.8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=4)
    g1 = make_loss_and_grad(cfg, 1)
    g2 = make_loss_and_grad(cfg, 2)
    loss1, _, grads1 = g1(params, batch)
    loss2, _, grads2 = g2(params, batch)
    assert abs(float(loss1) - float(loss2)) < 5e-3
    flat1, flat2 = jax.tree.leaves(grads1), jax.tree.leaves(grads2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_int8_grad_compression_trains():
    cfg = smoke_config("qwen3-8b")
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=50)
    mesh = make_host_mesh()
    with mesh:
        step, _ = make_sharded_train_step(cfg, opt, mesh, 4, compress=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = make_train_state(cfg, opt, params, compress=True)
        assert "err" in state
        losses = []
        for i in range(4):
            params, state, metrics = step(params, state, _batch(cfg, step=i))
            losses.append(float(metrics["total_loss"]))
    assert all(np.isfinite(x) for x in losses)


def test_compression_error_feedback_bounds_bias():
    """Error feedback: quantization residual is carried, not dropped."""
    from repro.train.train_step import compress_grads_int8
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = {"w": jnp.zeros((64,), jnp.float32)}
    acc = np.zeros(64, np.float32)
    true_acc = np.zeros(64, np.float32)
    for _ in range(50):
        deq, err = compress_grads_int8(grads, err)
        acc += np.asarray(deq["w"])
        true_acc += np.asarray(grads["w"])
    # accumulated compressed gradient tracks the true sum (EF property)
    assert np.abs(acc - true_acc).max() < 0.1


def test_train_driver_end_to_end_with_restart():
    from repro.launch.train import main as train_main
    with tempfile.TemporaryDirectory() as d:
        out1 = train_main(["--arch", "h2o-danube-1.8b", "--smoke",
                           "--steps", "6", "--batch", "2", "--seq", "32",
                           "--ckpt-dir", d, "--ckpt-every", "3",
                           "--log-every", "100"])
        assert np.isfinite(out1["last_loss"])
        # resume: supervisor restores step 6 and runs to 8
        out2 = train_main(["--arch", "h2o-danube-1.8b", "--smoke",
                           "--steps", "8", "--batch", "2", "--seq", "32",
                           "--ckpt-dir", d, "--ckpt-every", "4",
                           "--log-every", "100"])
        assert np.isfinite(out2["last_loss"])


def test_serve_driver_all_decoding_families():
    from repro.launch.serve import main as serve_main
    for arch in ("qwen3-8b", "zamba2-2.7b"):
        out = serve_main(["--arch", arch, "--smoke",
                          "--requests", "2", "--max-new", "4"])
        assert out["tokens"].shape == (2, 4)


def test_ual_system_flow_shares_session_cache(ual_cache):
    """The UAL end-to-end driver path: Program -> Target -> compile ->
    run/validate, with the compile memoized in the session cache (same
    cache every other test file uses, so the kernel maps at most once
    per test session)."""
    from repro import ual
    program = ual.Program.from_kernel("nw")
    target = ual.Target.from_name("hycube", rows=4, cols=4)
    misses0 = ual_cache.stats.misses
    exe = ual.compile(program, target)
    assert exe.success
    rep = exe.validate(seed=1, backends=("sim", "pallas"))
    assert rep.passed and rep.backend_results == {"sim": True, "pallas": True}
    # an identical recompile must be a pure cache hit
    hits0 = ual_cache.stats.hits
    exe2 = ual.compile(program, target)
    assert exe2.compile_info.cache_hit
    assert exe2.compile_info.mapper_restarts == 0
    assert ual_cache.stats.hits == hits0 + 1
    assert ual_cache.stats.misses <= misses0 + 1
    # dict-in/dict-out execution round-trips the named I/O spec
    out = exe2.run(**program.random_inputs(np.random.default_rng(0)))
    assert set(out) == set(program.arrays)

"""Optimizer, data pipeline, checkpoint/restart, elastic restore,
fault-tolerance supervisor, straggler monitor, sharding specs."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import latest_step, restore, save
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, Prefetcher, host_batch
from repro.models.common import init_params
from repro.runtime.fault_tolerance import (FaultConfig, StragglerMonitor,
                                           Supervisor, WorkerFailure)
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   lr_schedule)


def test_adamw_reduces_quadratic():
    opt = OptConfig(lr=0.1, warmup_steps=1, total_steps=100,
                    weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params, opt)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_factored_adam_matches_direction():
    opt = OptConfig(lr=0.01, factored=True, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.ones((8, 4))}
    state = init_opt_state(params, opt)
    assert "v_row" in state["state"]["w"] and "v" not in state["state"]["w"]
    grads = {"w": jnp.ones((8, 4))}
    params2, state, _ = adamw_update(params, grads, state, opt)
    assert (params2["w"] < params["w"]).all()


def test_lr_schedule_warmup_and_decay():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert lr_schedule(opt, 5) < lr_schedule(opt, 10)
    assert lr_schedule(opt, 99) < lr_schedule(opt, 20)


def test_data_determinism_across_host_counts():
    cfg = smoke_config("qwen3-8b")
    dc = DataConfig(global_batch=8, seq_len=16)
    full = host_batch(cfg, dc, step=3, host_id=0, n_hosts=1)
    h0 = host_batch(cfg, dc, step=3, host_id=0, n_hosts=2)
    h1 = host_batch(cfg, dc, step=3, host_id=1, n_hosts=2)
    np.testing.assert_array_equal(full["tokens"],
                                  np.concatenate([h0["tokens"], h1["tokens"]]))


def test_prefetcher_yields_sequential_steps():
    cfg = smoke_config("qwen3-8b")
    dc = DataConfig(global_batch=4, seq_len=8)
    pf = Prefetcher(cfg, dc, start_step=7)
    s0, b0 = next(pf)
    s1, b1 = next(pf)
    pf.close()
    assert (s0, s1) == (7, 8)
    np.testing.assert_array_equal(b0["tokens"],
                                  host_batch(cfg, dc, 7)["tokens"])


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
        for s in (10, 20, 30, 40):
            save(d, s, tree, keep=2)
        assert latest_step(d) == 40
        assert len(os.listdir(d)) == 2          # gc keeps 2
        restored, manifest = restore(d, jax.tree.map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(restored["a"], tree["a"])
        assert manifest["step"] == 40


def test_supervisor_restart_resumes_deterministically():
    with tempfile.TemporaryDirectory() as d:
        def make_state():
            return {"x": jnp.zeros(3)}

        def step_fn(state, step):
            return {"x": state["x"] + 1.0}

        cfg = FaultConfig(ckpt_dir=d, ckpt_every=2, max_restarts=3)
        crashed = {"done": False}

        def failure_hook(step):
            if step == 5 and not crashed["done"]:
                crashed["done"] = True
                return WorkerFailure(1, "injected node failure")
            return None

        sup = Supervisor(cfg, make_state=make_state, step_fn=step_fn)
        state = sup.run(8, failure_hook=failure_hook)
        assert sup.restarts == 1
        # restarted from step-4 checkpoint, continued to 8
        np.testing.assert_allclose(np.asarray(state["x"]), 8.0)


def test_straggler_monitor_flags_persistent_laggard():
    m = StragglerMonitor(factor=2.0, strikes_to_fail=2)
    assert m.observe(0, 1.0) is None
    assert m.observe(0, 1.0) is None
    assert m.observe(0, 5.0) == "straggler"
    assert m.observe(0, 5.0) == "fail"


def test_elastic_restore_onto_host_mesh():
    """Restore a checkpoint with explicit shardings (resize-on-load path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(8.0)}
        save(d, 1, tree)
        sh = {"w": NamedSharding(mesh, P("data"))}
        restored, _ = restore(d, jax.tree.map(jnp.zeros_like, tree),
                              shardings=sh)
        np.testing.assert_array_equal(restored["w"], tree["w"])
        assert restored["w"].sharding == sh["w"]


def test_param_specs_cover_tree():
    """Every param leaf has a matching PartitionSpec of equal rank."""
    from jax.sharding import PartitionSpec
    from repro.sharding.specs import param_specs
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    for arch in ("qwen3-8b", "deepseek-moe-16b", "rwkv6-1.6b", "zamba2-2.7b",
                 "hubert-xlarge", "paligemma-3b", "arctic-480b"):
        cfg = smoke_config(arch)
        params = jax.eval_shape(lambda k, c=cfg: init_params(k, c),
                                jax.random.PRNGKey(0))
        specs = param_specs(cfg, mesh)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = {tuple(str(x) for x in path): s for path, s in
                  jax.tree_util.tree_flatten_with_path(
                      specs, is_leaf=lambda x: isinstance(x, PartitionSpec))[0]}
        for path, leaf in flat_p:
            key = tuple(str(x) for x in path)
            assert key in flat_s, f"{arch}: no spec for {key}"
            assert len(flat_s[key]) <= leaf.ndim, \
                f"{arch}: spec rank > leaf rank at {key}"
"""DFG IR, builder, jaxpr extraction, interpreter, data layout."""
import numpy as np
import pytest

from repro.core.dfg import (DFGBuilder, apply_layout, flat_memory, interpret,
                            plan_layout, trace_into, unflatten_memory)
from repro.core.kernel_lib import KERNELS


@pytest.mark.parametrize("kname", sorted(KERNELS))
def test_kernels_build_and_interpret(kname):
    dfg, mk, n = KERNELS[kname]()
    rng = np.random.default_rng(0)
    out = interpret(dfg, mk(rng), n)
    for name in dfg.outputs:
        assert name in out
        assert out[name].dtype == np.int32
    assert dfg.topo_order()  # acyclic over dist==0 edges


def test_gemm_matches_numpy():
    dfg, mk, n = KERNELS["gemm"]()
    rng = np.random.default_rng(7)
    mem = mk(rng)
    out = interpret(dfg, mem, n)
    want = np.int32((mem["A"].astype(np.int64) * mem["B"].astype(np.int64)).sum())
    assert out["C"][0] == want


def test_nw_matches_reference_dp():
    dfg, mk, n = KERNELS["nw"]()
    rng = np.random.default_rng(3)
    mem = mk(rng)
    out = interpret(dfg, mem, n)
    left, row = 0, []
    for j in range(n):
        m = 1 if mem["seqa"][j] == mem["seqb"][j] else -1
        s = max(mem["above"][j] + m, mem["above"][j + 1] - 1, left - 1)
        left = s
        row.append(s)
    np.testing.assert_array_equal(out["row"], np.array(row, np.int32))


def test_jaxpr_extraction_matches_jax():
    import jax.numpy as jnp
    b = DFGBuilder("t")
    b.array("x", 8)
    b.array("y", 8, output=True)
    i = b.counter()
    x = b.load("x", i)

    def f(v):
        return jnp.where(v > 2, v * v - 1, v + 5) & 0xFF

    (o,) = trace_into(b, f, [x])
    b.store("y", i, o)
    dfg = b.build()
    rng = np.random.default_rng(0)
    xs = rng.integers(-10, 10, 8).astype(np.int32)
    out = interpret(dfg, {"x": xs}, 8)
    want = np.where(xs > 2, xs * xs - 1, xs + 5) & 0xFF
    np.testing.assert_array_equal(out["y"], want.astype(np.int32))


def test_recurrence_init_semantics():
    b = DFGBuilder("acc")
    b.array("out", 4, output=True)
    i = b.counter()
    acc = b.recur(init=100)
    acc2 = b.op("ADD", acc, 1)
    b.bind(acc, acc2)
    b.store("out", i, acc2)
    out = interpret(b.build(), {}, 4)
    np.testing.assert_array_equal(out["out"], [101, 102, 103, 104])


def test_layout_round_robin_and_flat_roundtrip():
    dfg, mk, _ = KERNELS["fft"]()
    lay = plan_layout(dfg, n_banks=4, bank_words=512)
    banks = set(lay.banks.values())
    assert len(banks) > 1, "arrays should spread across banks"
    rng = np.random.default_rng(0)
    mem = mk(rng)
    flat = flat_memory(lay, mem)
    back = unflatten_memory(lay, flat, dfg.arrays)
    for k, v in mem.items():
        np.testing.assert_array_equal(back[k], v)


def test_layout_folds_bases_into_consts():
    dfg, _, _ = KERNELS["gemm"]()
    lay = plan_layout(dfg)
    laid = apply_layout(dfg, lay)
    for n, m in zip(dfg.nodes, laid.nodes):
        if n.op in ("LOAD", "STORE"):
            assert (m.const or 0) == (n.const or 0) + lay.bases[n.array]


def test_recurrence_cycles_found():
    dfg, _, _ = KERNELS["nw"]()
    cycles = dfg.recurrence_cycles()
    assert cycles, "nw has a left-cell recurrence"

"""Compile-time config verifier: differential corruption fuzzing.

Strategy: compile real kernels to real configs (which must verify
CLEAN on every registered temporal fabric), then inject one corruption
class at a time into a cloned config and assert the verifier reports
exactly the expected diagnostic code.  The injections mirror the hazard
classes the engines would otherwise only hit at runtime — or never
(silent-``K_NONE`` wire collapses).
"""
from dataclasses import replace

import numpy as np
import pytest

from repro import ual
from repro.analysis.verifier import (CODES, CheckReport, Diagnostic,
                                     VerifyError, raise_if_errors, verify)
from repro.core.adl import Fabric
from repro.core.lowering import K_NONE, link_config
from repro.core.machine import (OPC, SRC_IN, SRC_REG, XB_IN, XB_NONE, XB_O,
                                MachineConfig)
from repro.core.simulator import BatchedSimulator

TEMPORAL_FABRICS = (("hycube", dict(rows=4, cols=4)),
                    ("n2n", dict(rows=4, cols=4)),
                    ("pace", {}))


def _compiled(kernel, fab_name, kwargs):
    target = ual.Target.from_name(fab_name, **kwargs)
    program = ual.Program.from_kernel(
        kernel, n_banks=max(1, target.fabric.n_mem_ports))
    exe = ual.compile(program, target)
    assert exe.success, f"{kernel} must map onto {fab_name}"
    return program, target, exe


def _clone(cfg: MachineConfig, fabric: Fabric = None) -> MachineConfig:
    return replace(cfg, fabric=fabric if fabric is not None else cfg.fabric,
                   opcode=cfg.opcode.copy(), const=cfg.const.copy(),
                   use_const=cfg.use_const.copy(), t0=cfg.t0.copy(),
                   node_id=cfg.node_id.copy(), op_src=cfg.op_src.copy(),
                   xbar=cfg.xbar.copy(), regw=cfg.regw.copy())


def _firing_locus(cfg):
    """First (slot, pe) holding a non-NOP instruction."""
    for s in range(cfg.II):
        for p in range(cfg.fabric.n_pes):
            if cfg.opcode[s, p] != OPC["NOP"]:
                return s, p
    raise AssertionError("config has no instructions")


@pytest.fixture(scope="module")
def gemm_hycube():
    return _compiled("gemm", "hycube", dict(rows=4, cols=4))


# -- clean configs: zero findings on every registered temporal fabric -------

@pytest.mark.parametrize("kernel", ["gemm", "fft"])
@pytest.mark.parametrize("fab_name,kwargs", TEMPORAL_FABRICS,
                         ids=[f[0] for f in TEMPORAL_FABRICS])
def test_clean_configs_verify_clean(kernel, fab_name, kwargs):
    program, target, exe = _compiled(kernel, fab_name, kwargs)
    rep = verify(cfg=exe.map_result.config, linked=exe.lowered,
                 program=program)
    assert rep.diagnostics == [], rep.render()
    assert rep.ok and rep.counts() == {"errors": 0, "warnings": 0,
                                       "infos": 0}
    # the pipeline already verified: the Executable carries the report
    assert exe.check_report is not None and exe.check_report.ok


# -- corruption injections: each class -> its expected code -----------------

def test_port_oversubscription_ual001(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    f1 = Fabric.from_json(cfg.fabric.to_json())
    f1.n_mem_ports = 1          # gemm needs >1 port somewhere in the II
    rep = verify(cfg=_clone(cfg, f1), program=program)
    assert "UAL001" in rep.codes() and not rep.ok
    d = next(d for d in rep.diagnostics if d.code == "UAL001")
    assert d.slot is not None and "port" in d.message


def test_dangling_wire_ual004(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    f = cfg.fabric
    bad = _clone(cfg)
    for s in range(cfg.II):
        driven = {li for p in range(f.n_pes)
                  for j, li in enumerate(f.out_links(p))
                  if bad.xbar[s, p, j, 0] != XB_NONE}
        undriven = [li for li in range(len(f.links)) if li not in driven]
        firing = [p for p in range(f.n_pes)
                  if bad.opcode[s, p] != OPC["NOP"]]
        if undriven and firing:
            bad.op_src[s, firing[0], 0] = (SRC_IN, undriven[0], 0, 0)
            break
    else:
        pytest.skip("no slot with an undriven link and a firing PE")
    rep = verify(cfg=bad, program=program)
    assert "UAL004" in rep.codes() and not rep.ok
    # differential: lowering collapses the same select to a silent K_NONE
    # and counts it — exactly the bug class the verifier makes loud
    linked = link_config(bad)
    assert linked.unresolved_inputs >= 1
    rep2 = verify(linked=linked, program=program)   # tables-only fallback
    assert "UAL004" in rep2.codes() and not rep2.ok


def test_hop_budget_excess_ual005(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    f = cfg.fabric
    bad = _clone(cfg)
    links = [tuple(l_pair) for l_pair in f.links]
    path = [0, 1, 2, 3, 7, 11, 15]       # 6 hops > hycube's max_hops=4
    assert f.max_hops < len(path) - 1
    s, prev_li = 0, None
    for a, b in zip(path, path[1:]):
        li = links.index((a, b))
        j = f.out_links(a).index(li)
        bad.xbar[s, a, j] = (XB_O, 0) if prev_li is None else (XB_IN,
                                                              prev_li)
        prev_li = li
    p = next(p for p in range(f.n_pes) if bad.opcode[s, p] != OPC["NOP"])
    bad.op_src[s, p, 0] = (SRC_IN, prev_li, 0, 0)
    rep = verify(cfg=bad, program=program)
    assert "UAL005" in rep.codes() and not rep.ok
    d = next(d for d in rep.diagnostics if d.code == "UAL005")
    assert f"{len(path) - 1}-hop" in d.message


def test_out_of_range_reg_ual008(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    bad = _clone(cfg)
    s, p = _firing_locus(bad)
    bad.op_src[s, p, 0] = (SRC_REG, bad.regw.shape[2] + 2, 0, 0)
    rep = verify(cfg=bad, program=program)
    assert "UAL008" in rep.codes() and not rep.ok


def test_schedule_inconsistency_ual009(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    bad = _clone(cfg)
    s, p = _firing_locus(bad)
    bad.t0[s, p] = int(bad.t0[s, p]) + 1      # t0 % II no longer == slot
    rep = verify(cfg=bad, program=program)
    assert "UAL009" in rep.codes() and not rep.ok


def test_write_write_race_ual002_and_overlap_ual003(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    bad = _clone(cfg)
    mem_pes = sorted(set(link_config(cfg).mem_pes))
    assert len(mem_pes) >= 2
    s = 1
    for p in mem_pes[:2]:      # two const-addr STOREs, same slot+address
        bad.opcode[s, p] = OPC["STORE"]
        bad.const[s, p] = 3
        bad.use_const[s, p] = 1
        bad.t0[s, p] = s
        bad.op_src[s, p, :] = 0
    rep = verify(cfg=bad, program=program)
    assert "UAL002" in rep.codes() and not rep.ok
    # turn one writer into a reader: write-write becomes load/store overlap
    overlap = _clone(bad)
    overlap.opcode[s, mem_pes[0]] = OPC["LOAD"]
    rep2 = verify(cfg=overlap, program=program)
    assert "UAL002" not in rep2.codes()
    assert "UAL003" in rep2.codes()


def test_const_addr_out_of_bounds_ual012(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    bad = _clone(cfg)
    p = sorted(set(link_config(cfg).mem_pes))[0]
    s = 1
    bad.opcode[s, p] = OPC["STORE"]
    bad.const[s, p] = program.layout.total_words + 100
    bad.use_const[s, p] = 1
    bad.t0[s, p] = s
    bad.op_src[s, p, :] = 0
    rep = verify(cfg=bad, program=program)
    assert "UAL012" in rep.codes() and not rep.ok
    # without a program (no layout), bounds are unknowable: no UAL012
    assert "UAL012" not in verify(cfg=bad).codes()


def test_mem_op_on_non_mem_pe_ual010(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    non_mem = sorted(set(range(cfg.fabric.n_pes))
                     - set(link_config(cfg).mem_pes))
    if not non_mem:
        pytest.skip("every PE on this fabric has scratchpad access")
    bad = _clone(cfg)
    s, p = 0, non_mem[0]
    bad.opcode[s, p] = OPC["LOAD"]
    bad.const[s, p] = 0
    bad.use_const[s, p] = 1
    bad.t0[s, p] = s
    bad.op_src[s, p, :] = 0
    rep = verify(cfg=bad, program=program)
    assert "UAL010" in rep.codes() and not rep.ok


def test_dead_code_warning_ual007(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    bad = _clone(cfg)
    for s in range(cfg.II):
        idle = [p for p in range(cfg.fabric.n_pes)
                if bad.opcode[s, p] == OPC["NOP"]]
        if idle:
            p = idle[0]
            bad.opcode[s, p] = OPC["MOVC"]     # result feeds nothing
            bad.const[s, p] = 7
            bad.use_const[s, p] = 1
            bad.t0[s, p] = s
            bad.op_src[s, p, :] = 0
            break
    else:
        pytest.skip("fully utilized config, nowhere to hide dead code")
    rep = verify(cfg=bad, program=program)
    assert rep.ok                              # warnings don't fail verify
    assert "UAL007" in rep.codes()
    assert rep.counts()["warnings"] >= 1


def test_use_before_def_warning_ual006(gemm_hycube):
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    bad = _clone(cfg)
    s, p = _firing_locus(bad)
    # read a register no slot ever writes on this PE
    linked = link_config(bad)
    unwritten = [r for r in range(linked.n_regs)
                 if not any(linked.regw[t, p, r, 0] != K_NONE
                            for t in range(linked.II))]
    if not unwritten:
        pytest.skip("every register of this PE is written somewhere")
    bad.op_src[s, p, 0] = (SRC_REG, unwritten[0], 0, 0)
    rep = verify(cfg=bad, program=program)
    assert "UAL006" in rep.codes()


# -- report / registry mechanics -------------------------------------------

def test_code_registry_is_stable():
    assert set(CODES) == {f"UAL{i:03d}" for i in range(1, 13)}
    for code, (severity, meaning) in CODES.items():
        assert severity in ("error", "warning", "info")
        assert meaning


def test_report_rendering_and_json():
    rep = CheckReport(name="k @ f", diagnostics=[
        Diagnostic("UAL001", "error", "too many", slot=2),
        Diagnostic("UAL007", "warning", "dead", slot=0, pe=3)])
    text = rep.render()
    assert "verify k @ f:" in text and "UAL001" in text
    assert "[slot 0/pe 3]" in text
    j = rep.to_json()
    assert j["ok"] is False and j["codes"] == ["UAL001", "UAL007"]
    assert j["diagnostics"][0]["slot"] == 2
    with pytest.raises(VerifyError) as ei:
        raise_if_errors(rep)
    assert ei.value.report is rep and "UAL001" in str(ei.value)
    clean = CheckReport(name="x")
    assert raise_if_errors(clean) is clean
    assert clean.summary() == "clean (0 findings)"


def test_verify_requires_an_input():
    with pytest.raises(ValueError):
        verify()


# -- pipeline / service integration ----------------------------------------

def test_compile_rejects_corrupted_config(gemm_hycube):
    """Acceptance: a deliberately corrupted cached config fails
    ``ual.compile()`` with a rendered UAL*** diagnostic."""
    program, target, exe = gemm_hycube
    bad = _clone(exe.map_result.config)
    s, p = _firing_locus(bad)
    bad.op_src[s, p, 0] = (SRC_REG, bad.regw.shape[2] + 2, 0, 0)
    cache = ual.MappingCache(disk_dir=None)
    cache.put((program.digest, target.digest),
              replace(exe.map_result, config=bad))
    with pytest.raises(VerifyError) as ei:
        ual.compile(program, target, cache=cache)
    assert "UAL008" in str(ei.value)
    assert not ei.value.report.ok
    # collect mode: same corrupt config, no raise, report on the exe
    loose = ual.compile(program, target, cache=cache,
                        pipeline=ual.default_pipeline(strict_verify=False))
    assert loose.check_report is not None
    assert "UAL008" in loose.check_report.codes()
    # the verify pass is on the pass record either way
    assert any(p.name == "verify" for p in loose.compile_info.passes)


def test_warning_only_config_still_compiles_and_runs(gemm_hycube):
    """Acceptance: warning-only findings produce a runnable Executable
    carrying the report — they never abort the compile."""
    program, target, exe = gemm_hycube
    warn = _clone(exe.map_result.config)
    for s in range(warn.II):
        idle = [p for p in range(warn.fabric.n_pes)
                if warn.opcode[s, p] == OPC["NOP"]]
        if idle:
            p = idle[0]
            warn.opcode[s, p] = OPC["MOVC"]
            warn.const[s, p] = 7
            warn.use_const[s, p] = 1
            warn.t0[s, p] = s
            warn.op_src[s, p, :] = 0
            break
    cache = ual.MappingCache(disk_dir=None)
    cache.put((program.digest, target.digest),
              replace(exe.map_result, config=warn))
    exe2 = ual.compile(program, target, cache=cache)   # strict: no raise
    rep = exe2.check_report
    assert rep is not None and rep.ok and rep.counts()["warnings"] >= 1
    out = exe2.run(**program.random_inputs(np.random.default_rng(0)))
    assert set(out) == set(program.arrays)


def test_service_rejects_verifier_error(gemm_hycube):
    program, target, exe = gemm_hycube
    bad = _clone(exe.map_result.config)
    s, p = _firing_locus(bad)
    bad.op_src[s, p, 0] = (SRC_REG, bad.regw.shape[2] + 2, 0, 0)
    cache = ual.MappingCache(disk_dir=None)
    cache.put((program.digest, target.digest),
              replace(exe.map_result, config=bad))
    svc = ual.Service(max_batch=4, max_wait_ms=1.0, cache=cache)
    try:
        fut = svc.submit(program, target,
                         **program.random_inputs(np.random.default_rng(0)))
        with pytest.raises(ual.ServiceRejected) as ei:
            fut.result(timeout=60)
        assert ei.value.reason == "verifier-error"
        assert "UAL008" in str(ei.value)
        assert svc.stats()["rejects"].get("verifier-error") == 1
    finally:
        svc.shutdown()


# -- satellite: n_mem_ports threading + the limit-0 guard semantics ---------

def test_linked_config_threads_fabric_port_limit(gemm_hycube):
    program, target, exe = gemm_hycube
    assert exe.lowered.n_mem_ports == target.fabric.n_mem_ports
    assert target.fabric.n_mem_ports > 0
    assert exe.lowered.unresolved_inputs == 0


def test_port_limit_zero_disables_guard_but_records_pressure(gemm_hycube):
    """``n_mem_ports == 0`` means unknown/unbounded: the batched engine's
    runtime guard must not raise, pressure is still in the stats, and the
    verifier says so (UAL011 info)."""
    program, _, exe = gemm_hycube
    cfg = exe.map_result.config
    linked = link_config(cfg)
    # static steady-state pressure from the tables
    static_peak = max(
        sum(1 for p in range(linked.n_pes)
            if int(linked.scalar[s, p, 0]) in (OPC["LOAD"], OPC["STORE"])
            and linked.scalar[s, p, 3] >= 0)
        for s in range(linked.II))
    assert static_peak >= 1
    mem = program.random_inputs(np.random.default_rng(0))
    flat = program.flatten(mem)

    unlimited = replace(linked, n_mem_ports=0)
    sim = BatchedSimulator(unlimited)
    _, stats = sim.run(flat[None, :].copy(), program.n_iters,
                       check_ports=True)    # limit 0 short-circuits: no raise
    assert stats.max_mem_ports_used == static_peak
    assert not stats.oversubscribed
    rep = verify(linked=unlimited, program=program)
    assert "UAL011" in rep.codes() and rep.ok     # info-severity only

    strangled = replace(linked, n_mem_ports=1)
    if static_peak > 1:
        with pytest.raises(RuntimeError, match="port"):
            BatchedSimulator(strangled).run(flat[None, :].copy(),
                                            program.n_iters,
                                            check_ports=True)
        assert "UAL001" in verify(linked=strangled,
                                  program=program).codes()
